"""Pallas kernel: masked mailbox mean (APAN's aggregation primitive).

APAN (Wang et al. 2021) delivers messages ("mails") to neighbor mailboxes
asynchronously and aggregates the mailbox at embedding time. The rust
coordinator maintains the per-vertex mailbox ring buffer; this kernel
performs the masked mean over the K most recent mails. (APAN's attention
variant reuses kernels/attention.py with mails as keys/values.)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import common, ref


def _kernel(x_ref, m_ref, o_ref):
    x = x_ref[...]
    mask = m_ref[...]
    num = jnp.sum(x * mask[:, :, None], axis=1)
    den = jnp.maximum(jnp.sum(mask, axis=1, keepdims=True), 1.0)
    o_ref[...] = num / den


@common.ref_vjp(ref.masked_mean)
def masked_mean(x, mask):
    """x: [b, K, d], mask: [b, K] -> [b, d]. See ref.masked_mean."""
    b, K, d = x.shape
    bb = common.pick_block_b(b)
    return common.call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct((b, d), jnp.float32),
        grid=(b // bb,),
        in_specs=[
            common.row_spec(bb, K, d),
            common.row_spec(bb, K),
        ],
        out_specs=common.row_spec(bb, d),
    )(x, mask)
