"""Pallas kernel: JODIE's time-projected embedding h = s * (1 + dt * w).

JODIE (Kumar et al. 2019) evolves an embedding between events by a learned
linear drift in elapsed time; this is its EMB module and the only compute
between memory rows and decoder, so it is kerneled despite being small.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import common, ref


def _kernel(s_ref, dt_ref, w_ref, o_ref):
    s = s_ref[...]
    dt = dt_ref[...]
    o_ref[...] = s * (1.0 + dt[:, None] * w_ref[...][None, :])


@common.ref_vjp(ref.jodie_project)
def jodie_project(s, dt, w):
    """s: [b, d], dt: [b], w: [d] -> [b, d]. See ref.jodie_project."""
    b, d = s.shape
    bb = common.pick_block_b(b)
    return common.call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct((b, d), jnp.float32),
        grid=(b // bb,),
        in_specs=[
            common.row_spec(bb, d),
            common.row_spec(bb),
            common.full_spec(d),
        ],
        out_specs=common.row_spec(bb, d),
    )(s, dt, w)
