"""Pallas kernel: fused GRU memory cell (the MEM module's hot spot).

One matmul per operand against a fused [.., 3*dh] gate bank (cuDNN layout)
instead of three separate gate GEMMs: on TPU this feeds the MXU two large
[block_b, dx|dh] x [dx|dh, 3*dh] tiles per block (dh=64 -> 192-wide bank,
MXU-aligned), then finishes the gate nonlinearity in VPU registers.

VMEM per block (block_b=128, dx=dh=64, f32):
  x 32KB + h 32KB + wx 48KB + wh 48KB + bias 1.5KB + out 32KB ~ 0.19 MB.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import common, ref


def _kernel(x_ref, h_ref, wx_ref, wh_ref, b_ref, o_ref):
    x = x_ref[...]
    h = h_ref[...]
    bias = b_ref[...]
    dh = h.shape[1]
    gx = jnp.dot(x, wx_ref[...]) + bias[0][None, :]
    gh = jnp.dot(h, wh_ref[...]) + bias[1][None, :]
    r = jax.nn.sigmoid(gx[:, :dh] + gh[:, :dh])
    z = jax.nn.sigmoid(gx[:, dh : 2 * dh] + gh[:, dh : 2 * dh])
    n = jnp.tanh(gx[:, 2 * dh :] + r * gh[:, 2 * dh :])
    o_ref[...] = (1.0 - z) * n + z * h


@common.ref_vjp(ref.fused_gru)
def fused_gru(x, h, wx, wh, bias):
    """x: [b, dx], h: [b, dh], wx: [dx, 3dh], wh: [dh, 3dh], bias: [2, 3dh].

    Returns the next memory state [b, dh]. See ref.fused_gru.
    """
    b, dx = x.shape
    dh = h.shape[1]
    bb = common.pick_block_b(b)
    return common.call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct((b, dh), jnp.float32),
        grid=(b // bb,),
        in_specs=[
            common.row_spec(bb, dx),
            common.row_spec(bb, dh),
            common.full_spec(dx, 3 * dh),
            common.full_spec(dh, 3 * dh),
            common.full_spec(2, 3 * dh),
        ],
        out_specs=common.row_spec(bb, dh),
    )(x, h, wx, wh, bias)
