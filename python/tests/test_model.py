"""L2 model invariants: step semantics that the rust coordinator relies on.

These tests pin down the ABI behaviour the coordinator assumes: STANDARD
mode recovery (pres_on=0), lag-one splice correctness, coherence bounds,
Adam updates, and that a few steps of training on a learnable toy stream
actually reduce the loss.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model

jax.config.update("jax_platform_name", "cpu")

B = 8
R = np.random.default_rng(0)


def _data(model_name, b=B, pres_on=0.0, beta=0.0, seed=1):
    r = np.random.default_rng(seed)
    out = []
    for name, shape, dtype in model.data_input_specs(model_name, b):
        if name == "beta":
            arr = np.float32(beta)
        elif name == "pres_on":
            arr = np.float32(pres_on)
        elif dtype == "i32":
            arr = np.full(shape, -1, np.int32)
        elif name.endswith("_mask") or name == "u_wmask":
            arr = r.integers(0, 2, size=shape).astype(np.float32)
        elif name.endswith("_dt"):
            arr = r.uniform(0, 5, size=shape).astype(np.float32)
        else:
            arr = (r.normal(size=shape) * 0.5).astype(np.float32)
        out.append(jnp.asarray(arr))
    return out


def _params_list(model_name, seed=0):
    p = model.init_params(model_name, seed)
    return [p[n] for n, _, _ in model.param_specs(model_name)]


def _run_eval(model_name, data, b=B):
    fn, inputs, outs = model.make_step(model_name, b, "eval")
    res = fn(*(_params_list(model_name) + data))
    return {n: r for (n, _, _), r in zip(outs, res)}


@pytest.mark.parametrize("m", model.MODELS)
def test_eval_output_shapes(m):
    out = _run_eval(m, _data(m))
    assert out["u_sbar"].shape == (2 * B, model.DIMS["d_mem"])
    assert out["u_delta"].shape == (2 * B, model.DIMS["d_mem"])
    assert out["u_msg"].shape == (2 * B, model.DIMS["d_msg"])
    assert out["pos_logit"].shape == (B,)
    assert out["neg_logit"].shape == (B,)
    assert out["loss"].shape == ()
    for v in out.values():
        assert np.all(np.isfinite(np.asarray(v)))


@pytest.mark.parametrize("m", model.MODELS)
def test_standard_mode_ignores_prediction(m):
    """pres_on=0 must make the step independent of u_pred (gamma forced to 1)
    and produce zero innovation — this is how STANDARD shares the artifact."""
    data1 = _data(m, pres_on=0.0, seed=2)
    data2 = list(data1)
    idx = [n for n, _, _ in model.data_input_specs(m, B)].index("u_pred")
    data2[idx] = data2[idx] + 100.0
    o1, o2 = _run_eval(m, data1), _run_eval(m, data2)
    np.testing.assert_allclose(o1["u_sbar"], o2["u_sbar"], atol=1e-6)
    np.testing.assert_allclose(o1["loss"], o2["loss"], atol=1e-6)
    np.testing.assert_allclose(o1["u_delta"], np.zeros_like(o1["u_delta"]), atol=1e-6)


@pytest.mark.parametrize("m", model.MODELS)
def test_pres_mode_uses_prediction(m):
    data1 = _data(m, pres_on=1.0, seed=3)
    data2 = list(data1)
    idx = [n for n, _, _ in model.data_input_specs(m, B)].index("u_pred")
    data2[idx] = data2[idx] + 1.0
    o1, o2 = _run_eval(m, data1), _run_eval(m, data2)
    assert not np.allclose(o1["u_sbar"], o2["u_sbar"], atol=1e-4)
    # innovation must be nonzero when prediction differs from update
    assert float(np.abs(np.asarray(o1["u_delta"])).max()) > 1e-6


def test_coherence_in_unit_interval():
    for m in model.MODELS:
        out = _run_eval(m, _data(m, seed=4))
        c = float(out["coherence"])
        assert -1.0 - 1e-5 <= c <= 1.0 + 1e-5


def test_splice_selects_updated_rows():
    """A current-batch vertex matched to update-row j must embed from the
    corrected state s_bar[j], not the store value."""
    m = "jodie"  # embedding = projected memory -> easiest to observe
    names = [n for n, _, _ in model.data_input_specs(m, B)]
    data = _data(m, seed=5)
    # give src row 0 a match to update row 3, dt 0 so embedding == memory
    match = np.full(B, -1, np.int32)
    match[0] = 3
    data[names.index("c_src_match")] = jnp.asarray(match)
    dt = np.asarray(data[names.index("c_src_dt")]).copy()
    dt[0] = 0.0
    data[names.index("c_src_dt")] = jnp.asarray(dt)

    out = _run_eval(m, data)
    # reconstruct: with dt=0, JODIE embedding is the memory itself; decoder
    # consumes it, so instead check via u_sbar: rerun with c_src_mem[0]
    # perturbed — output must NOT change (the splice overrides the store row).
    data2 = list(data)
    csm = np.asarray(data2[names.index("c_src_mem")]).copy()
    csm[0] += 50.0
    data2[names.index("c_src_mem")] = jnp.asarray(csm)
    out2 = _run_eval(m, data2)
    np.testing.assert_allclose(out["pos_logit"][0], out2["pos_logit"][0], atol=1e-5)

    # and without the match, the same perturbation must change the logit
    data3 = list(data2)
    data3[names.index("c_src_match")] = jnp.asarray(np.full(B, -1, np.int32))
    out3 = _run_eval(m, data3)
    assert not np.allclose(out["pos_logit"][0], out3["pos_logit"][0], atol=1e-3)


def test_beta_scales_coherence_penalty():
    m = "tgn"
    o0 = _run_eval(m, _data(m, beta=0.0, seed=6))
    o1 = _run_eval(m, _data(m, beta=0.5, seed=6))
    expected = float(o0["loss"]) + 0.5 * (1.0 - float(o0["coherence"]))
    np.testing.assert_allclose(float(o1["loss"]), expected, rtol=1e-5)


@pytest.mark.parametrize("m", model.MODELS)
def test_train_step_improves_loss_on_fixed_batch(m):
    """A few Adam steps on one fixed batch must reduce the BCE (sanity that
    gradients flow through msg/mem/emb/decoder and the splice)."""
    fn, inputs, outs = model.make_step(m, B, "train")
    params = _params_list(m)
    mstate = [jnp.zeros_like(p) for p in params]
    vstate = [jnp.zeros_like(p) for p in params]
    data = _data(m, pres_on=1.0, beta=0.1, seed=7)
    jfn = jax.jit(fn)
    n_p = len(params)
    out_names = [n for n, _, _ in outs]
    first_bce = last_bce = None
    for t in range(1, 16):
        res = jfn(*params, *mstate, *vstate, *data, jnp.float32(1e-2), jnp.float32(t))
        params = list(res[:n_p])
        mstate = list(res[n_p : 2 * n_p])
        vstate = list(res[2 * n_p : 3 * n_p])
        bce = float(res[out_names.index("bce")])
        if first_bce is None:
            first_bce = bce
        last_bce = bce
    assert last_bce < first_bce * 0.9, (first_bce, last_bce)


def test_train_matches_manual_adam():
    """One train step == eval forward + jax.grad + reference Adam."""
    m = "jodie"
    fn_t, _, outs_t = model.make_step(m, B, "train")
    params = _params_list(m)
    n_p = len(params)
    data = _data(m, pres_on=1.0, beta=0.2, seed=8)
    mstate = [jnp.zeros_like(p) for p in params]
    vstate = [jnp.zeros_like(p) for p in params]
    lr, t = 1e-2, 1.0

    res = fn_t(*params, *mstate, *vstate, *data, jnp.float32(lr), jnp.float32(t))
    got_params = res[:n_p]

    # manual reference
    names = [n for n, _, _ in model.param_specs(m)]
    dspecs = model.data_input_specs(m, B)

    def loss_fn(pl):
        d = {n: a for (n, _, _), a in zip(dspecs, data)}
        loss, _ = model._forward(m, {n: a for n, a in zip(names, pl)}, d)
        return loss

    grads = jax.grad(loss_fn)(params)
    for p, g, gp in zip(params, grads, got_params):
        mm = (1 - model.ADAM_B1) * g
        vv = (1 - model.ADAM_B2) * g * g
        step = lr * (mm / (1 - model.ADAM_B1**t)) / (
            jnp.sqrt(vv / (1 - model.ADAM_B2**t)) + model.ADAM_EPS
        )
        np.testing.assert_allclose(np.asarray(p - step), np.asarray(gp), atol=1e-5)


def test_clf_step_learns_separable_labels():
    fn, inputs, outs = model.make_clf_step("train")
    b = model.DIMS["clf_batch"]
    demb = model.DIMS["d_emb"]
    r = np.random.default_rng(9)
    w_true = r.normal(size=demb).astype(np.float32)
    emb = r.normal(size=(b, demb)).astype(np.float32)
    labels = (emb @ w_true > 0).astype(np.float32)
    weight = np.ones(b, np.float32)

    params = [model.init_params("clf", 0)[n] for n, _, _ in model.clf_param_specs()]
    mstate = [jnp.zeros_like(p) for p in params]
    vstate = [jnp.zeros_like(p) for p in params]
    jfn = jax.jit(fn)
    n_p = len(params)
    losses = []
    for t in range(1, 40):
        res = jfn(
            *params, *mstate, *vstate,
            jnp.asarray(emb), jnp.asarray(labels), jnp.asarray(weight),
            jnp.float32(5e-2), jnp.float32(t),
        )
        params = list(res[:n_p])
        mstate = list(res[n_p : 2 * n_p])
        vstate = list(res[2 * n_p : 3 * n_p])
        losses.append(float(res[3 * n_p]))
    assert losses[-1] < losses[0] * 0.5, (losses[0], losses[-1])


def test_clf_weight_masks_padding():
    fn, _, _ = model.make_clf_step("train")
    b = model.DIMS["clf_batch"]
    demb = model.DIMS["d_emb"]
    r = np.random.default_rng(10)
    emb = r.normal(size=(b, demb)).astype(np.float32)
    labels = r.integers(0, 2, size=b).astype(np.float32)
    weight = np.ones(b, np.float32)
    weight[b // 2 :] = 0.0

    params = [model.init_params("clf", 0)[n] for n, _, _ in model.clf_param_specs()]
    zeros = [jnp.zeros_like(p) for p in params]

    res1 = fn(*params, *zeros, *zeros, jnp.asarray(emb), jnp.asarray(labels),
              jnp.asarray(weight), jnp.float32(1e-2), jnp.float32(1))
    # flipping labels in the masked half must not change the loss
    labels2 = labels.copy()
    labels2[b // 2 :] = 1.0 - labels2[b // 2 :]
    res2 = fn(*params, *zeros, *zeros, jnp.asarray(emb), jnp.asarray(labels2),
              jnp.asarray(weight), jnp.float32(1e-2), jnp.float32(1))
    np.testing.assert_allclose(float(res1[12]), float(res2[12]), atol=1e-6)
