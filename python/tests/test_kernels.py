"""Kernel vs. oracle: the core L1 correctness signal.

Hypothesis sweeps shapes (including non-divisible batch sizes that stress
the block picker) and value ranges; every pallas kernel must match its
pure-jnp reference to float32 tolerance, and its custom-VJP gradients must
match jax.grad of the reference.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import kernels
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

ATOL = 1e-4
RTOL = 1e-4


def _rng(seed):
    return np.random.default_rng(seed)


def _close(a, b, atol=ATOL, rtol=RTOL):
    # f32 kernels vs f32 reference: forward passes agree to ~1e-5; gradient
    # comparisons accumulate over reductions, so callers pass looser bounds.
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=atol, rtol=rtol)


# ---------------------------------------------------------------- time_encode


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 300),
    d=st.integers(1, 48),
    seed=st.integers(0, 2**31 - 1),
)
def test_time_encode_matches_ref(n, d, seed):
    r = _rng(seed)
    dt = jnp.asarray(r.uniform(0, 50, size=n), jnp.float32)
    omega = jnp.asarray(r.normal(size=d), jnp.float32)
    phi = jnp.asarray(r.normal(size=d), jnp.float32)
    _close(kernels.time_encode(dt, omega, phi), ref.time_encode(dt, omega, phi))


def test_time_encode_grads_match_ref():
    r = _rng(0)
    dt = jnp.asarray(r.uniform(0, 50, size=64), jnp.float32)
    omega = jnp.asarray(r.normal(size=16), jnp.float32)
    phi = jnp.asarray(r.normal(size=16), jnp.float32)
    f_k = lambda *a: jnp.sum(kernels.time_encode(*a) ** 2)
    f_r = lambda *a: jnp.sum(ref.time_encode(*a) ** 2)
    gk = jax.grad(f_k, argnums=(0, 1, 2))(dt, omega, phi)
    gr = jax.grad(f_r, argnums=(0, 1, 2))(dt, omega, phi)
    for a, b in zip(gk, gr):
        _close(a, b, atol=2e-3, rtol=2e-3)


# ------------------------------------------------------------------ fused_gru


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 260),
    dx=st.sampled_from([8, 32, 64]),
    dh=st.sampled_from([8, 32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_gru_matches_ref(b, dx, dh, seed):
    r = _rng(seed)
    x = jnp.asarray(r.normal(size=(b, dx)), jnp.float32)
    h = jnp.asarray(r.normal(size=(b, dh)), jnp.float32)
    wx = jnp.asarray(r.normal(size=(dx, 3 * dh)) * 0.1, jnp.float32)
    wh = jnp.asarray(r.normal(size=(dh, 3 * dh)) * 0.1, jnp.float32)
    bias = jnp.asarray(r.normal(size=(2, 3 * dh)) * 0.1, jnp.float32)
    _close(kernels.fused_gru(x, h, wx, wh, bias), ref.fused_gru(x, h, wx, wh, bias))


def test_fused_gru_gate_semantics():
    """z == 1 (huge update-gate bias) must return h unchanged."""
    b, dx, dh = 4, 8, 8
    r = _rng(1)
    x = jnp.asarray(r.normal(size=(b, dx)), jnp.float32)
    h = jnp.asarray(r.normal(size=(b, dh)), jnp.float32)
    wx = jnp.zeros((dx, 3 * dh), jnp.float32)
    wh = jnp.zeros((dh, 3 * dh), jnp.float32)
    bias = np.zeros((2, 3 * dh), np.float32)
    bias[0, dh : 2 * dh] = 100.0  # update gate saturated at 1
    out = kernels.fused_gru(x, h, wx, wh, jnp.asarray(bias))
    _close(out, h)


def test_fused_gru_grads_match_ref():
    r = _rng(2)
    b, dx, dh = 32, 16, 16
    args = (
        jnp.asarray(r.normal(size=(b, dx)), jnp.float32),
        jnp.asarray(r.normal(size=(b, dh)), jnp.float32),
        jnp.asarray(r.normal(size=(dx, 3 * dh)) * 0.1, jnp.float32),
        jnp.asarray(r.normal(size=(dh, 3 * dh)) * 0.1, jnp.float32),
        jnp.asarray(r.normal(size=(2, 3 * dh)) * 0.1, jnp.float32),
    )
    f_k = lambda *a: jnp.sum(kernels.fused_gru(*a) ** 2)
    f_r = lambda *a: jnp.sum(ref.fused_gru(*a) ** 2)
    gk = jax.grad(f_k, argnums=tuple(range(5)))(*args)
    gr = jax.grad(f_r, argnums=tuple(range(5)))(*args)
    for a, b_ in zip(gk, gr):
        _close(a, b_, atol=2e-3, rtol=2e-3)


# --------------------------------------------------------- temporal_attention


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 200),
    K=st.integers(1, 16),
    heads=st.sampled_from([1, 2, 4]),
    dk=st.sampled_from([4, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_matches_ref(b, K, heads, dk, seed):
    r = _rng(seed)
    q = jnp.asarray(r.normal(size=(b, heads * dk)), jnp.float32)
    k = jnp.asarray(r.normal(size=(b, K, heads * dk)), jnp.float32)
    v = jnp.asarray(r.normal(size=(b, K, heads * dk)), jnp.float32)
    mask = jnp.asarray(r.integers(0, 2, size=(b, K)), jnp.float32)
    _close(
        kernels.temporal_attention(q, k, v, mask, heads),
        ref.temporal_attention(q, k, v, mask, heads),
    )


def test_attention_fully_masked_rows_are_zero():
    r = _rng(3)
    b, K, heads, dk = 8, 5, 2, 8
    q = jnp.asarray(r.normal(size=(b, heads * dk)), jnp.float32)
    k = jnp.asarray(r.normal(size=(b, K, heads * dk)), jnp.float32)
    v = jnp.asarray(r.normal(size=(b, K, heads * dk)), jnp.float32)
    mask = jnp.zeros((b, K), jnp.float32)
    out = kernels.temporal_attention(q, k, v, mask, heads)
    _close(out, jnp.zeros_like(out))


def test_attention_single_neighbor_passthrough():
    """With exactly one unmasked neighbor the output is that neighbor's value."""
    r = _rng(4)
    b, K, heads, dk = 6, 4, 2, 8
    q = jnp.asarray(r.normal(size=(b, heads * dk)), jnp.float32)
    k = jnp.asarray(r.normal(size=(b, K, heads * dk)), jnp.float32)
    v = jnp.asarray(r.normal(size=(b, K, heads * dk)), jnp.float32)
    mask = np.zeros((b, K), np.float32)
    mask[:, 2] = 1.0
    out = kernels.temporal_attention(q, k, v, jnp.asarray(mask), heads)
    _close(out, v[:, 2, :])


def test_attention_grads_match_ref():
    r = _rng(5)
    b, K, heads, dk = 16, 6, 2, 8
    q = jnp.asarray(r.normal(size=(b, heads * dk)), jnp.float32)
    k = jnp.asarray(r.normal(size=(b, K, heads * dk)), jnp.float32)
    v = jnp.asarray(r.normal(size=(b, K, heads * dk)), jnp.float32)
    mask = jnp.asarray(r.integers(0, 2, size=(b, K)), jnp.float32)
    f_k = lambda q, k, v: jnp.sum(kernels.temporal_attention(q, k, v, mask, heads) ** 2)
    f_r = lambda q, k, v: jnp.sum(ref.temporal_attention(q, k, v, mask, heads) ** 2)
    gk = jax.grad(f_k, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(f_r, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(gk, gr):
        _close(a, b_, atol=2e-3, rtol=2e-3)


# --------------------------------------------------------------- pres_correct


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 260),
    d=st.sampled_from([4, 32, 64]),
    gamma=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_pres_correct_matches_ref(b, d, gamma, seed):
    r = _rng(seed)
    s_new = jnp.asarray(r.normal(size=(b, d)), jnp.float32)
    s_pred = jnp.asarray(r.normal(size=(b, d)), jnp.float32)
    g = jnp.full((b,), gamma, jnp.float32)
    sk, dk_ = kernels.pres_correct(s_new, s_pred, g)
    sr, dr = ref.pres_correct(s_new, s_pred, g)
    _close(sk, sr)
    _close(dk_, dr)


def test_pres_correct_gamma_one_is_standard():
    """gamma = 1 recovers STANDARD training: s_bar == s_new, delta == 0."""
    r = _rng(6)
    s_new = jnp.asarray(r.normal(size=(32, 16)), jnp.float32)
    s_pred = jnp.asarray(r.normal(size=(32, 16)), jnp.float32)
    s_bar, delta = kernels.pres_correct(s_new, s_pred, jnp.ones((32,), jnp.float32))
    _close(s_bar, s_new)
    _close(delta, jnp.zeros_like(delta))


def test_pres_correct_gamma_zero_is_pure_prediction():
    r = _rng(7)
    s_new = jnp.asarray(r.normal(size=(32, 16)), jnp.float32)
    s_pred = jnp.asarray(r.normal(size=(32, 16)), jnp.float32)
    s_bar, delta = kernels.pres_correct(s_new, s_pred, jnp.zeros((32,), jnp.float32))
    _close(s_bar, s_pred)
    _close(delta, s_pred - s_new)


def test_pres_correct_grads_flow_to_gamma():
    r = _rng(8)
    s_new = jnp.asarray(r.normal(size=(32, 16)), jnp.float32)
    s_pred = jnp.asarray(r.normal(size=(32, 16)), jnp.float32)

    def loss(g):
        s_bar, _ = kernels.pres_correct(s_new, s_pred, g)
        return jnp.sum(s_bar**2)

    g0 = jnp.full((32,), 0.3, jnp.float32)
    g = jax.grad(loss)(g0)
    gr = jax.grad(lambda g_: jnp.sum(ref.pres_correct(s_new, s_pred, g_)[0] ** 2))(g0)
    _close(g, gr, atol=2e-3, rtol=2e-3)
    assert float(jnp.abs(g).max()) > 0.0


# -------------------------------------------------------------- jodie_project


@settings(max_examples=25, deadline=None)
@given(b=st.integers(1, 260), d=st.sampled_from([4, 32, 64]), seed=st.integers(0, 2**31 - 1))
def test_jodie_project_matches_ref(b, d, seed):
    r = _rng(seed)
    s = jnp.asarray(r.normal(size=(b, d)), jnp.float32)
    dt = jnp.asarray(r.uniform(0, 10, size=b), jnp.float32)
    w = jnp.asarray(r.normal(size=d) * 0.1, jnp.float32)
    _close(kernels.jodie_project(s, dt, w), ref.jodie_project(s, dt, w))


def test_jodie_project_zero_dt_identity():
    r = _rng(9)
    s = jnp.asarray(r.normal(size=(16, 8)), jnp.float32)
    w = jnp.asarray(r.normal(size=8), jnp.float32)
    _close(kernels.jodie_project(s, jnp.zeros(16, jnp.float32), w), s)


# ---------------------------------------------------------------- masked_mean


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 200),
    K=st.integers(1, 16),
    d=st.sampled_from([4, 32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_masked_mean_matches_ref(b, K, d, seed):
    r = _rng(seed)
    x = jnp.asarray(r.normal(size=(b, K, d)), jnp.float32)
    mask = jnp.asarray(r.integers(0, 2, size=(b, K)), jnp.float32)
    _close(kernels.masked_mean(x, mask), ref.masked_mean(x, mask))


def test_masked_mean_empty_mailbox_is_zero():
    x = jnp.ones((4, 5, 8), jnp.float32)
    out = kernels.masked_mean(x, jnp.zeros((4, 5), jnp.float32))
    _close(out, jnp.zeros_like(out))


def test_masked_mean_full_mask_is_mean():
    r = _rng(10)
    x = jnp.asarray(r.normal(size=(4, 5, 8)), jnp.float32)
    out = kernels.masked_mean(x, jnp.ones((4, 5), jnp.float32))
    _close(out, jnp.mean(x, axis=1))


# ----------------------------------------------------------- jit-compat smoke


def test_kernels_compose_under_jit():
    """The full kernel chain must lower under jit (the aot.py path)."""
    r = _rng(11)
    b, d, K, heads = 50, 64, 10, 2

    @jax.jit
    def chain(x, h, wx, wh, bias, q, kk, v, mask, gamma):
        s = kernels.fused_gru(x, h, wx, wh, bias)
        s_bar, delta = kernels.pres_correct(s, h, gamma)
        e = kernels.temporal_attention(q, kk, v, mask, heads)
        return jnp.sum(s_bar) + jnp.sum(e) + jnp.sum(delta)

    out = chain(
        jnp.asarray(r.normal(size=(b, d)), jnp.float32),
        jnp.asarray(r.normal(size=(b, d)), jnp.float32),
        jnp.asarray(r.normal(size=(d, 3 * d)) * 0.05, jnp.float32),
        jnp.asarray(r.normal(size=(d, 3 * d)) * 0.05, jnp.float32),
        jnp.asarray(r.normal(size=(2, 3 * d)) * 0.05, jnp.float32),
        jnp.asarray(r.normal(size=(b, d)), jnp.float32),
        jnp.asarray(r.normal(size=(b, K, d)), jnp.float32),
        jnp.asarray(r.normal(size=(b, K, d)), jnp.float32),
        jnp.asarray(r.integers(0, 2, size=(b, K)), jnp.float32),
        jnp.full((b,), 0.7, jnp.float32),
    )
    assert np.isfinite(float(out))
