"""AOT pipeline checks: HLO text is emitted, parseable-looking, and the
manifest ABI matches the model's declared specs exactly."""

import json
import os

import jax
import pytest

from compile import aot, model

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    aot.build(str(out), quick=True)
    with open(out / "manifest.json") as f:
        return out, json.load(f)


def test_manifest_lists_all_quick_artifacts(built):
    out, manifest = built
    names = {a["name"] for a in manifest["artifacts"]}
    for m, b in aot.QUICK_MATRIX:
        assert f"{m}_b{b}_train" in names
        assert f"{m}_b{b}_eval" in names
    assert "clf_train" in names and "clf_eval" in names


def test_hlo_files_exist_and_look_like_hlo(built):
    out, manifest = built
    for a in manifest["artifacts"]:
        path = os.path.join(out, a["file"])
        assert os.path.exists(path)
        head = open(path).read(4096)
        assert "HloModule" in head
        assert "ENTRY" in open(path).read()


def test_manifest_abi_matches_model_specs(built):
    _, manifest = built
    for a in manifest["artifacts"]:
        if a["model"] == "clf":
            _, inputs, outs = model.make_clf_step(a["kind"])
        else:
            _, inputs, outs = model.make_step(a["model"], a["batch"], a["kind"])
        assert a["inputs"] == aot._spec_json(inputs)
        assert a["outputs"] == aot._spec_json(outs)


def test_hlo_entry_arity_matches_manifest(built):
    """The ENTRY computation must take exactly len(inputs) parameters."""
    out, manifest = built
    for a in manifest["artifacts"]:
        text = open(os.path.join(out, a["file"])).read()
        lines = text[text.index("ENTRY") :].splitlines()
        body = []
        for line in lines[1:]:
            if line.strip() == "}":
                break
            body.append(line)
        n_params = sum(1 for line in body if " parameter(" in line)
        assert n_params == len(a["inputs"]), a["name"]


def test_param_specs_cover_every_model(built):
    _, manifest = built
    for m in model.MODELS:
        specs = manifest["params"][m]
        assert [tuple(s["shape"]) for s in specs] == [
            tuple(s) for _, s, _ in model.param_specs(m)
        ]
        # every init spec must be one of the kinds rust implements
        for s in specs:
            assert s["init"]["kind"] in ("glorot_uniform", "zeros", "const")


def test_dims_roundtrip(built):
    _, manifest = built
    assert manifest["dims"] == model.DIMS
