//! Quickstart: train a TGN with PRES on a tiny synthetic temporal graph.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! This is the 30-second tour: generate a stream, train a few epochs with
//! large temporal batches + PRES, print the val/test average precision.

use pres::config::ExperimentConfig;
use pres::training::Trainer;

fn main() -> anyhow::Result<()> {
    // "tiny" is a 3k-event bipartite interaction stream; PRES on, batch 50.
    let mut cfg = ExperimentConfig::default_with("tiny", "tgn", 50, true);
    cfg.epochs = 5;
    cfg.eval_every = 1;

    let mut trainer = Trainer::from_config(&cfg)?;
    println!("dataset: {} events", trainer.dataset.log.len());
    let (pend_frac, pend_pairs) = trainer.pending_summary();
    println!(
        "pending events in a batch: {:.0}% (avg {:.2} pending pairs/event)",
        pend_frac * 100.0,
        pend_pairs
    );

    for epoch in 0..cfg.epochs {
        let mut r = trainer.train_epoch(epoch)?;
        r.val_ap = trainer.eval_val()?;
        println!(
            "epoch {}: loss {:.4}  train AP {:.4}  val AP {:.4}  gamma {:.3}",
            epoch, r.train_loss, r.train_ap, r.val_ap, r.gamma
        );
    }
    let (test_ap, _) = trainer.eval_test(false)?;
    println!("test AP: {test_ap:.4}");
    Ok(())
}
