//! End-to-end driver (the EXPERIMENTS.md §E2E run): train a full MDGNN on
//! the WIKI-like stream for several hundred steps through all three layers
//! (rust coordinator -> AOT XLA step -> Pallas kernels), logging the loss
//! curve and writing it to results/e2e_loss_curve.csv.
//!
//!     cargo run --release --example e2e_train [-- --model tgn --batch 200 --epochs 8]

use pres::config::ExperimentConfig;
use pres::training::Trainer;
use pres::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1), &["std"])?;
    let model = args.get_or("model", "tgn");
    let batch = args.usize_or("batch", 200)?;
    let epochs = args.usize_or("epochs", 8)?;
    let mut cfg = ExperimentConfig::default_with("wiki", model, batch, !args.flag("std"));
    cfg.epochs = epochs;

    let mut trainer = Trainer::from_config(&cfg)?;
    let steps_per_epoch = trainer.dataset.split.train_end / batch;
    println!(
        "e2e: {} on wiki-like stream ({} events, {} steps/epoch x {} epochs, mode={})",
        model,
        trainer.dataset.log.len(),
        steps_per_epoch,
        epochs,
        if cfg.pres { "PRES" } else { "STANDARD" }
    );

    let mut curve: Vec<(usize, f64, f64)> = Vec::new(); // (iter, loss, ap)
    let mut total_iters = 0usize;
    for epoch in 0..epochs {
        let r = trainer.train_epoch(epoch)?;
        total_iters += steps_per_epoch.saturating_sub(1);
        let val_ap = trainer.eval_val()?;
        println!(
            "epoch {:>2}: loss {:.4}  bce {:.4}  coherence {:.4}  val AP {:.4}  \
             ({:.0} events/s, {:.2}s)",
            epoch, r.train_loss, r.train_bce, r.coherence, val_ap, r.events_per_sec,
            r.epoch_secs
        );
        curve.push((total_iters, r.train_loss, val_ap));
    }
    let (test_ap, rows) = trainer.eval_test(true)?;
    let auc = pres::eval::nodeclf::train_and_auc(&trainer.engine, &rows, cfg.seed)?;
    println!("final: test AP {test_ap:.4}  node-clf AUC {auc:.4}");

    // per-iteration loss curve (the §E2E artifact)
    std::fs::create_dir_all("results")?;
    let mut csv = String::from("iteration,train_batch_ap\n");
    for (it, ap) in &trainer.iteration_ap {
        csv.push_str(&format!("{it},{ap:.5}\n"));
    }
    std::fs::write("results/e2e_iteration_ap.csv", csv)?;
    let mut csv = String::from("iterations,epoch_train_loss,val_ap\n");
    for (it, loss, ap) in &curve {
        csv.push_str(&format!("{it},{loss:.5},{ap:.5}\n"));
    }
    std::fs::write("results/e2e_loss_curve.csv", csv)?;
    println!("wrote results/e2e_loss_curve.csv and results/e2e_iteration_ap.csv");
    Ok(())
}
