//! Dynamic node classification (Table 2's protocol) as a standalone example:
//! train the encoder self-supervised, freeze it, replay the stream to
//! collect dynamic embeddings for labeled events, train the MLP head, and
//! report ROC-AUC on the chronological tail.
//!
//!     cargo run --release --example node_classification [-- --dataset mooc --model tgn]

use pres::config::ExperimentConfig;
use pres::training::Trainer;
use pres::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1), &["std"])?;
    let dataset = args.get_or("dataset", "mooc");
    let model = args.get_or("model", "tgn");
    let mut cfg = ExperimentConfig::default_with(dataset, model, 200, !args.flag("std"));
    cfg.epochs = args.usize_or("epochs", 5)?;

    println!("stage 1: self-supervised encoder training ({model} on {dataset}-like)");
    let mut trainer = Trainer::from_config(&cfg)?;
    for epoch in 0..cfg.epochs {
        let r = trainer.train_epoch(epoch)?;
        println!("  epoch {}: loss {:.4} train AP {:.4}", epoch, r.train_loss, r.train_ap);
    }

    println!("stage 2: replay stream, collect labeled dynamic embeddings");
    let (test_ap, rows) = trainer.eval_test(true)?;
    let positives = rows.iter().filter(|(_, l)| *l > 0.5).count();
    println!(
        "  {} labeled events ({} positive), link-pred test AP {:.4}",
        rows.len(),
        positives,
        test_ap
    );

    println!("stage 3: train the classification head, report tail ROC-AUC");
    let auc = pres::eval::nodeclf::train_and_auc(&trainer.engine, &rows, cfg.seed)?;
    println!("  node classification ROC-AUC: {auc:.4}");
    Ok(())
}
