//! Batch-scaling demo: the paper's core phenomenon on one screen.
//!
//! Trains the same model at increasing temporal batch sizes with and
//! without PRES and prints AP + epoch time side by side — a miniature of
//! Fig. 4 + Table 1.
//!
//!     cargo run --release --example batch_scaling [-- --dataset wiki --model tgn]

use std::rc::Rc;
use std::sync::Arc;

use pres::config::ExperimentConfig;
use pres::runtime::Engine;
use pres::training::Trainer;
use pres::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1), &[])?;
    let dataset = args.get_or("dataset", "wiki");
    let model = args.get_or("model", "tgn");
    let epochs = args.usize_or("epochs", 4)?;

    let engine = Rc::new(Engine::new(std::path::Path::new("artifacts"))?);
    let base_cfg = ExperimentConfig::default_with(dataset, model, 100, false);
    let ds = Arc::new(Trainer::make_dataset(&base_cfg)?);

    println!(
        "{:>7} {:>14} {:>14} {:>12} {:>12}",
        "batch", "STANDARD AP", "PRES AP", "std s/epoch", "pres s/epoch"
    );
    for batch in [50, 100, 200, 400, 800] {
        let mut row = format!("{batch:>7}");
        let mut times = Vec::new();
        for pres in [false, true] {
            let mut cfg = ExperimentConfig::default_with(dataset, model, batch, pres);
            cfg.epochs = epochs;
            let mut tr = Trainer::with_shared(&cfg, engine.clone(), ds.clone())?;
            let mut secs = 0.0;
            for e in 0..cfg.epochs {
                secs += tr.train_epoch(e)?.epoch_secs;
            }
            let ap = tr.eval_val()?;
            row.push_str(&format!(" {ap:>14.4}"));
            times.push(secs / cfg.epochs as f64);
        }
        println!("{row} {:>12.2} {:>12.2}", times[0], times[1]);
    }
    println!("\nPRES holds AP as the batch grows; STANDARD degrades (Fig. 4's shape).");
    Ok(())
}
